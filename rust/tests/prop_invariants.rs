//! Randomized property tests over decomposition invariants — the
//! definitional checks, run against the *fast* pipeline (not just the
//! oracles): k-wing/k-tip membership conditions, monotonicity, and
//! counting identities.

use pbng::count::{brute, pve_bcnt, CountOptions};
use pbng::engine::EngineConfig;
use pbng::graph::{gen, GraphBuilder, Side};
use pbng::testkit::{check_property, Rng};
use pbng::tip::{tip_pbng, TipConfig};
use pbng::wing::{wing_pbng, PbngConfig};

fn random_graph(seed: u64) -> pbng::graph::BipartiteGraph {
    let mut rng = Rng::new(seed);
    match rng.usize_below(3) {
        0 => gen::erdos(5 + rng.usize_below(20), 5 + rng.usize_below(20), 20 + rng.usize_below(100), seed),
        1 => gen::zipf(8 + rng.usize_below(25), 8 + rng.usize_below(25), 30 + rng.usize_below(150), 1.0 + rng.f64(), 1.0 + rng.f64(), seed),
        _ => gen::planted_blocks(
            40,
            40,
            20 + rng.usize_below(60),
            &[gen::Block { rows: 3 + rng.usize_below(5), cols: 3 + rng.usize_below(5), density: 0.8 }],
            seed,
        ),
    }
}

/// Defn. 1 half: every edge with θ_e = k participates in ≥ k butterflies
/// within the subgraph of edges with θ ≥ k.
#[test]
fn wing_numbers_satisfy_min_support_in_level() {
    check_property("wing-level-support", 0x1001, 10, |seed| {
        let g = random_graph(seed);
        if g.m() == 0 {
            return Ok(());
        }
        let theta = wing_pbng(&g, PbngConfig { p: 4, threads: 2, ..Default::default() }).theta;
        for k in theta.iter().copied().collect::<std::collections::BTreeSet<_>>() {
            if k == 0 {
                continue;
            }
            let alive: Vec<bool> = theta.iter().map(|&t| t >= k).collect();
            let sup = brute::edge_support_restricted(&g, &alive);
            for e in 0..g.m() {
                if theta[e] == k && sup[e] < k {
                    return Err(format!("edge {e}: θ={k} but only {} butterflies in level", sup[e]));
                }
            }
        }
        Ok(())
    });
}

/// Maximality half: an edge's support in the (θ_e + 1)-level must be
/// below θ_e + 1 (otherwise its wing number would be higher).
#[test]
fn wing_numbers_are_maximal() {
    check_property("wing-maximality", 0x1002, 8, |seed| {
        let g = random_graph(seed);
        if g.m() == 0 {
            return Ok(());
        }
        let theta = wing_pbng(&g, PbngConfig { p: 3, threads: 2, ..Default::default() }).theta;
        let brute_theta = brute::brute_wing_numbers(&g);
        if theta != brute_theta {
            return Err("pipeline disagrees with definitional oracle".into());
        }
        Ok(())
    });
}

/// Tip numbers: same definitional bracket on the vertex side.
#[test]
fn tip_numbers_satisfy_min_support_in_level() {
    check_property("tip-level-support", 0x1003, 10, |seed| {
        let g = random_graph(seed);
        let theta = tip_pbng(&g, Side::U, TipConfig { p: 3, threads: 2, ..Default::default() }).theta;
        for k in theta.iter().copied().collect::<std::collections::BTreeSet<_>>() {
            if k == 0 {
                continue;
            }
            let alive: Vec<bool> = theta.iter().map(|&t| t >= k).collect();
            let sup = brute::vertex_support_restricted(&g, &alive);
            for u in 0..g.nu() {
                if theta[u] == k && sup[u] < k {
                    return Err(format!("u{u}: θ={k} but {} butterflies in level", sup[u]));
                }
            }
        }
        Ok(())
    });
}

/// Adding an edge can only raise (or keep) wing numbers of existing edges.
#[test]
fn wing_numbers_monotone_under_edge_addition() {
    check_property("wing-monotone-add", 0x1004, 8, |seed| {
        let mut rng = Rng::new(seed);
        let g = gen::erdos(8, 8, 25, seed);
        if g.m() == 0 {
            return Ok(());
        }
        let t1 = brute::brute_wing_numbers(&g);
        // add one random absent edge
        let mut extra = None;
        for _ in 0..100 {
            let u = rng.below(8) as u32;
            let v = rng.below(8) as u32;
            if !g.has_edge(u, v) {
                extra = Some((u, v));
                break;
            }
        }
        let Some(extra) = extra else { return Ok(()) };
        let mut edges: Vec<(u32, u32)> = g.edges().to_vec();
        edges.push(extra);
        let g2 = GraphBuilder::new().nu(8).nv(8).edges(&edges).build();
        let t2 = brute::brute_wing_numbers(&g2);
        for e2 in 0..g2.m() as u32 {
            let (u, v) = g2.edge(e2);
            if (u, v) == extra {
                continue;
            }
            let e1 = g.edge_id(u, v).unwrap();
            if t2[e2 as usize] < t1[e1 as usize] {
                return Err(format!("θ({u},{v}) dropped after adding {extra:?}"));
            }
        }
        Ok(())
    });
}

/// Adding an edge can only raise (or keep) tip numbers of the existing
/// vertices — the vertex-side mirror of the wing property above, and the
/// monotonicity `engine::incremental` leans on for insert streams.
#[test]
fn tip_numbers_monotone_under_edge_addition() {
    check_property("tip-monotone-add", 0x1006, 8, |seed| {
        let mut rng = Rng::new(seed);
        let g = gen::erdos(8, 8, 25, seed);
        // add one random absent edge
        let mut extra = None;
        for _ in 0..100 {
            let u = rng.below(8) as u32;
            let v = rng.below(8) as u32;
            if !g.has_edge(u, v) {
                extra = Some((u, v));
                break;
            }
        }
        let Some(extra) = extra else { return Ok(()) };
        let mut edges: Vec<(u32, u32)> = g.edges().to_vec();
        edges.push(extra);
        let g2 = GraphBuilder::new().nu(8).nv(8).edges(&edges).build();
        for side in [Side::U, Side::V] {
            let t1 = brute::brute_tip_numbers(&g, side);
            let t2 = brute::brute_tip_numbers(&g2, side);
            for (x, (&a, &b)) in t1.iter().zip(&t2).enumerate() {
                if b < a {
                    return Err(format!(
                        "{side:?} vertex {x}: θ dropped {a} → {b} after adding {extra:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Removing an edge can only lower (or keep) wing numbers of the
/// surviving edges — the deletion direction of the same invariant.
#[test]
fn wing_numbers_monotone_under_edge_deletion() {
    check_property("wing-monotone-del", 0x1007, 8, |seed| {
        let mut rng = Rng::new(seed);
        let g = gen::erdos(8, 8, 28, seed);
        if g.m() == 0 {
            return Ok(());
        }
        let t1 = brute::brute_wing_numbers(&g);
        let victim = rng.usize_below(g.m());
        let edges: Vec<(u32, u32)> = g
            .edges()
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != victim)
            .map(|(_, &e)| e)
            .collect();
        let g2 = GraphBuilder::new().nu(8).nv(8).edges(&edges).build();
        let t2 = brute::brute_wing_numbers(&g2);
        for e2 in 0..g2.m() as u32 {
            let (u, v) = g2.edge(e2);
            let e1 = g.edge_id(u, v).expect("surviving edge");
            if t2[e2 as usize] > t1[e1 as usize] {
                return Err(format!(
                    "θ({u},{v}) rose {} → {} after removing edge {victim}",
                    t1[e1 as usize], t2[e2 as usize]
                ));
            }
        }
        Ok(())
    });
}

/// Counting identities on the fast counter: Σ per-edge = 4·total,
/// Σ per-u = Σ per-v = 2·total.
#[test]
fn counting_identities() {
    check_property("count-identities", 0x1005, 12, |seed| {
        let g = random_graph(seed);
        let (c, _) = pve_bcnt(
            &g,
            CountOptions { per_edge: true, build_blooms: false, threads: 2 },
            None,
        );
        let su: u64 = c.per_u.iter().sum();
        let sv: u64 = c.per_v.iter().sum();
        let se: u64 = c.per_edge.iter().sum();
        if su != 2 * c.total || sv != 2 * c.total || se != 4 * c.total {
            return Err(format!(
                "identities broken: total={} Σu={su} Σv={sv} Σe={se}",
                c.total
            ));
        }
        Ok(())
    });
}

/// Isolated vertices and empty graphs don't break any pipeline.
#[test]
fn degenerate_inputs() {
    // empty graph
    let g = GraphBuilder::new().nu(5).nv(5).build();
    let d = wing_pbng(&g, PbngConfig::default());
    assert!(d.theta.is_empty());
    let t = tip_pbng(&g, Side::U, EngineConfig::tip());
    assert!(t.theta.iter().all(|&x| x == 0));
    // single edge
    let g = GraphBuilder::new().edges(&[(0, 0)]).build();
    let d = wing_pbng(&g, PbngConfig::default());
    assert_eq!(d.theta, vec![0]);
    // star (no butterflies)
    let g = GraphBuilder::new()
        .edges(&[(0, 0), (1, 0), (2, 0), (3, 0)])
        .build();
    let d = wing_pbng(&g, PbngConfig { p: 3, ..Default::default() });
    assert!(d.theta.iter().all(|&x| x == 0));
    let t = tip_pbng(&g, Side::V, TipConfig { p: 2, ..Default::default() });
    assert_eq!(t.theta, vec![0]);
}
