//! Runtime-pool integration: the PR's acceptance criteria.
//!
//! * θ vectors must be byte-identical across thread counts {1, 2, 8} for
//!   both entity types (wing + tip) on the zipf and grid generators —
//!   catches pool races and lane-ordering bugs.
//! * A full PBNG wing run must spawn at most pool-capacity OS threads
//!   (bounded by the pool size, not by ρ), and a warm pool must spawn
//!   none at all — the "no per-region thread spawning" criterion.

use pbng::graph::{gen, Side};
use pbng::tip::{tip_pbng, TipConfig};
use pbng::wing::{wing_pbng, PbngConfig};

fn graphs() -> Vec<(&'static str, pbng::graph::BipartiteGraph)> {
    vec![
        ("zipf", gen::zipf(90, 90, 600, 1.2, 1.2, 93)),
        ("grid", gen::grid(80, 80, 4, 0.9, 94)),
    ]
}

#[test]
fn wing_theta_identical_across_thread_counts() {
    for (name, g) in graphs() {
        let reference = wing_pbng(&g, PbngConfig { p: 6, threads: 1, ..Default::default() }).theta;
        for threads in [2, 8] {
            let got = wing_pbng(&g, PbngConfig { p: 6, threads, ..Default::default() }).theta;
            assert_eq!(got, reference, "wing θ diverged on {name} at threads={threads}");
        }
    }
}

#[test]
fn tip_theta_identical_across_thread_counts() {
    for (name, g) in graphs() {
        for side in [Side::U, Side::V] {
            let reference =
                tip_pbng(&g, side, TipConfig { p: 4, threads: 1, ..Default::default() }).theta;
            for threads in [2, 8] {
                let got =
                    tip_pbng(&g, side, TipConfig { p: 4, threads, ..Default::default() }).theta;
                assert_eq!(
                    got,
                    reference,
                    "tip θ diverged on {name} {side:?} at threads={threads}"
                );
            }
        }
    }
}

#[test]
fn spin_before_park_preserves_warm_pool_correctness() {
    use std::sync::atomic::{AtomicU64, Ordering};
    // warm the pool (no-op region)
    pbng::par::spmd(4, |_| {});
    let before = pbng::par::total_spawns();
    let hits: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
    // thousands of back-to-back sub-microsecond regions: whether a worker
    // catches a region on the spin path or after parking, the lane
    // contract (every logical id exactly once per region) must hold,
    // and a warm pool must never fall back to spawning threads
    for _ in 0..2_000 {
        pbng::par::spmd(4, |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
    }
    for (t, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 2_000, "lane {t} miscounted");
    }
    assert_eq!(
        pbng::par::total_spawns(),
        before,
        "warm pool spawned threads across spin-paced regions"
    );
}

#[test]
fn full_wing_run_spawns_at_most_pool_capacity_threads() {
    // Run first, read the capacity after: if this test gets to create the
    // pool, the first run measures the real cold-start spawn delta
    // (capacity − 1); if a sibling test already warmed it, the delta is 0.
    // The bound holds either way, and the second run is always warm.
    let g = gen::zipf(70, 70, 450, 1.2, 1.2, 95);
    let d = wing_pbng(&g, PbngConfig { p: 6, threads: 8, ..Default::default() });
    let capacity = pbng::par::pool_capacity() as u64;
    assert!(d.stats.rho >= 1, "run must execute peel iterations");
    assert!(
        d.stats.spawns <= capacity,
        "spawned {} threads over a run with rho={} — pool not persistent (capacity {})",
        d.stats.spawns,
        d.stats.rho,
        capacity
    );
    // The pool is warm now: a second full run — thousands of parallel
    // regions — must not create a single new OS thread.
    let d2 = wing_pbng(&g, PbngConfig { p: 6, threads: 8, ..Default::default() });
    assert_eq!(d2.stats.spawns, 0, "warm pool spawned threads; workers not reused");
    assert_eq!(d2.theta, d.theta);
}
