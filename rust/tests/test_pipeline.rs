//! Cross-module integration: full decomposition pipelines on preset
//! workloads, algorithm agreement at moderate scale, hierarchy
//! extraction on decomposition output.

use pbng::graph::{gen, Side};
use pbng::peel::bup::wing_bup;
use pbng::tip::{tip_bup, tip_pbng, TipConfig};
use pbng::wing::{wing_be_batch, wing_pbng, PbngConfig};

#[test]
fn wing_pipeline_on_presets() {
    for preset in [gen::Preset::PlantedS, gen::Preset::NestedS] {
        let g = preset.build();
        let pbng = wing_pbng(&g, PbngConfig { p: 16, threads: 4, ..Default::default() });
        let beb = wing_be_batch(&g, 4);
        assert_eq!(pbng.theta, beb.theta, "preset {}", preset.name());
        assert!(pbng.stats.rho > 0);
        assert!(pbng.stats.rho <= beb.stats.rho);
    }
}

#[test]
fn wing_pbng_equals_bup_on_medium_zipf() {
    let g = gen::zipf(300, 300, 2500, 1.2, 1.2, 1234);
    let a = wing_pbng(&g, PbngConfig { p: 12, threads: 4, ..Default::default() });
    let b = wing_bup(&g);
    assert_eq!(a.theta, b.theta);
    // two-phase pays at most ~2x the updates of sequential BUP w/ BE-index,
    // and usually far less thanks to batching
    assert!(a.stats.rho < g.m() as u64 / 4);
}

#[test]
fn tip_pipeline_both_sides_on_preset() {
    let g = gen::Preset::DiAfS.build();
    for side in [Side::U, Side::V] {
        let pbng = tip_pbng(&g, side, TipConfig { p: 8, threads: 4, ..Default::default() });
        let bup = tip_bup(&g, side);
        assert_eq!(pbng.theta, bup.theta, "side {side:?}");
    }
}

#[test]
fn hierarchy_from_pipeline_output_nests() {
    let g = gen::Preset::PlantedS.build();
    let (idx, _) = pbng::beindex::BeIndex::build(&g, 2);
    let d = wing_pbng(&g, PbngConfig { p: 8, threads: 2, ..Default::default() });
    pbng::hierarchy::check_wing_nesting(&g, &idx, &d.theta).unwrap();
    let summary = pbng::hierarchy::wing_hierarchy_summary(&g, &idx, &d.theta);
    assert!(!summary.is_empty());
    // planted dense blocks must produce a non-trivial hierarchy
    assert!(summary.len() >= 3, "levels: {}", summary.len());
}

#[test]
fn tip_and_wing_agree_on_max_levels() {
    // θ_E^max-level edges must connect vertices with high tip numbers
    let g = gen::Preset::PlantedS.build();
    let w = wing_pbng(&g, PbngConfig { p: 8, threads: 2, ..Default::default() });
    let t = tip_pbng(&g, Side::U, TipConfig { p: 8, threads: 2, ..Default::default() });
    let max_w = *w.theta.iter().max().unwrap();
    for e in 0..g.m() as u32 {
        if w.theta[e as usize] == max_w && max_w > 0 {
            let (u, _) = g.edge(e);
            assert!(
                t.theta[u as usize] >= max_w,
                "u{} tip {} < wing level {}",
                u,
                t.theta[u as usize],
                max_w
            );
        }
    }
}
