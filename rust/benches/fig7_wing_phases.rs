//! Fig. 7 — contribution of each step to wing decomposition: counting +
//! BE-Index construction, PBNG CD peeling, BE-Index partitioning, and
//! PBNG FD peeling — as % of support updates and of execution time.
//!
//! Shape to reproduce: CD dominates updates (>60% on most datasets); FD's
//! time share slightly exceeds its update share; count/partition are
//! cheap relative to peeling.

use pbng::graph::gen;
use pbng::metrics::Phase;
use pbng::wing::{wing_pbng, PbngConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Fig. 7 — phase breakdown of PBNG wing decomposition (% of total)");
    println!(
        "{:<12} | {:>8} {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8} {:>8}",
        "", "time%", "", "", "", "updates%", "", "", ""
    );
    println!(
        "{:<12} | {:>8} {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8} {:>8}",
        "dataset", "count", "CD", "part", "FD", "count", "CD", "part", "FD"
    );
    for p in presets {
        let g = p.build();
        let d = wing_pbng(&g, PbngConfig { p: 64, threads, ..Default::default() });
        let tt = d.stats.total.as_secs_f64().max(1e-12);
        let tu = (d.stats.updates as f64).max(1.0);
        let tp = |ph: Phase| 100.0 * d.stats.phase_time(ph).as_secs_f64() / tt;
        let up = |ph: Phase| 100.0 * d.stats.phase_updates(ph) as f64 / tu;
        println!(
            "{:<12} | {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% | {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            p.name(),
            tp(Phase::Count),
            tp(Phase::Coarse),
            tp(Phase::Partition),
            tp(Phase::Fine),
            up(Phase::Count),
            up(Phase::Coarse),
            up(Phase::Partition),
            up(Phase::Fine),
        );
    }
}
