//! Fig. 6 — effect of the §5 optimizations on wing decomposition:
//! PBNG (all), PBNG− (no dynamic BE-Index deletes), PBNG−− (additionally
//! no batch processing). Reports time, support updates, and bloom-edge
//! links traversed, normalized to full PBNG — the paper's Fig. 6 layout.
//!
//! Shape to reproduce: deletes cut traversal (~1.4× avg in the paper);
//! batching cuts updates and time dramatically (9.1× / 21× avg).

use pbng::graph::gen;
use pbng::metrics::human;
use pbng::wing::{wing_pbng, PbngConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Fig. 6 — wing optimization ablation (normalized to PBNG = 1.0)");
    println!(
        "{:<12} {:>22} {:>22} {:>22}",
        "dataset", "time (−/−−)", "updates (−/−−)", "links (−/−−)"
    );
    for p in presets {
        let g = p.build();
        let base = wing_pbng(&g, PbngConfig { p: 64, threads, ..Default::default() });
        let minus = wing_pbng(
            &g,
            PbngConfig { p: 64, threads, dynamic_deletes: false, ..Default::default() },
        );
        let minus2 = wing_pbng(
            &g,
            PbngConfig { p: 64, threads, batch: false, dynamic_deletes: false, ..Default::default() },
        );
        assert_eq!(base.theta, minus.theta);
        assert_eq!(base.theta, minus2.theta);
        let r = |a: f64, b: f64| if b > 0.0 { a / b } else { f64::NAN };
        println!(
            "{:<12} {:>10.2}/{:<10.2} {:>10.2}/{:<10.2} {:>10.2}/{:<10.2}   [PBNG: {:.2}s {} {}]",
            p.name(),
            r(minus.stats.total.as_secs_f64(), base.stats.total.as_secs_f64()),
            r(minus2.stats.total.as_secs_f64(), base.stats.total.as_secs_f64()),
            r(minus.stats.updates as f64, base.stats.updates as f64),
            r(minus2.stats.updates as f64, base.stats.updates as f64),
            r(minus.stats.wedges as f64, base.stats.wedges as f64),
            r(minus2.stats.wedges as f64, base.stats.wedges as f64),
            base.stats.total.as_secs_f64(),
            human(base.stats.updates),
            human(base.stats.wedges),
        );
    }
}
