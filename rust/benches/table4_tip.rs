//! Table 4 — tip decomposition comparison: execution time, wedges
//! traversed, and synchronization rounds ρ for BUP / ParB / PBNG, both
//! vertex sets of each dataset (U = higher-workload side by paper
//! convention; we report both).
//!
//! Shape to reproduce: PBNG fastest on every dataset; PBNG wedge counts
//! below BUP/ParB (batch re-counting + induced subgraphs); ρ reduced by
//! orders of magnitude.

use pbng::graph::{gen, Side};
use pbng::metrics::human;
use pbng::tip::{tip_bup, tip_parb, tip_pbng, TipConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Table 4 — tip decomposition: t(s), wedges, ρ");
    println!(
        "{:<14} {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10} | {:>8} {:>8}",
        "dataset", "t BUP", "t ParB", "t PBNG", "wdg BUP", "wdg ParB", "wdg PBNG", "ρ ParB", "ρ PBNG"
    );
    for p in presets {
        let g = p.build();
        for side in [Side::U, Side::V] {
            let name = format!("{}{}", p.name(), if side == Side::U { "U" } else { "V" });
            let bup = tip_bup(&g, side);
            let parb = tip_parb(&g, side, threads);
            let pbng_d = tip_pbng(&g, side, TipConfig { p: 32, threads, ..Default::default() });
            assert_eq!(pbng_d.theta, bup.theta, "{name}: PBNG != BUP");
            assert_eq!(parb.theta, bup.theta, "{name}: ParB != BUP");
            println!(
                "{:<14} {:>10.3} {:>10.3} {:>10.3} | {:>10} {:>10} {:>10} | {:>8} {:>8}",
                name,
                bup.stats.total.as_secs_f64(),
                parb.stats.total.as_secs_f64(),
                pbng_d.stats.total.as_secs_f64(),
                human(bup.stats.wedges),
                human(parb.stats.wedges),
                human(pbng_d.stats.wedges),
                parb.stats.rho,
                pbng_d.stats.rho,
            );
        }
    }
}
