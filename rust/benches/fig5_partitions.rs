//! Fig. 5 — PBNG wing decomposition time vs number of partitions P.
//!
//! Shape to reproduce: CD time decreases with smaller P (fewer, larger
//! batches); FD workload/parallelism favors larger P; total is robust
//! (within ~2× of optimum) over a wide P range.

use pbng::graph::gen;
use pbng::metrics::Phase;
use pbng::wing::{wing_pbng, PbngConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let presets: &[gen::Preset] = if full {
        &[gen::Preset::TrS, gen::Preset::OrS, gen::Preset::TrM]
    } else {
        &[gen::Preset::TrS, gen::Preset::OrS]
    };
    println!("Fig. 5 — execution time vs #partitions P (wing, PBNG)");
    println!(
        "{:<10} {:>6} {:>10} {:>10} {:>10} {:>8} {:>12}",
        "dataset", "P", "total(s)", "CD(s)", "FD(s)", "ρ", "updates"
    );
    for p in presets {
        let g = p.build();
        for parts in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            let d = wing_pbng(&g, PbngConfig { p: parts, threads, ..Default::default() });
            println!(
                "{:<10} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>8} {:>12}",
                p.name(),
                parts,
                d.stats.total.as_secs_f64(),
                d.stats.phase_time(Phase::Coarse).as_secs_f64(),
                d.stats.phase_time(Phase::Fine).as_secs_f64(),
                d.stats.rho,
                pbng::metrics::human(d.stats.updates),
            );
        }
    }
}
