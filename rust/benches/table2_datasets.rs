//! Table 2 — dataset statistics: |U|, |V|, |E|, total butterflies ⋈_G,
//! max tip numbers θ_U^max / θ_V^max, and max wing number θ_E^max.
//!
//! Paper's Table 2 lists the 12 KONECT datasets; this regenerates the
//! same columns for the synthetic stand-in suite (DESIGN.md
//! §Substitutions). `--full` adds the medium tier.

use pbng::engine::EngineConfig;
use pbng::graph::{gen, Side};
use pbng::metrics::human;
use pbng::tip::tip_pbng;
use pbng::wing::wing_pbng;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    println!("Table 2 — dataset statistics (synthetic stand-ins; see DESIGN.md)");
    println!(
        "{:<12} {:>8} {:>8} {:>9} {:>12} {:>10} {:>10} {:>9}",
        "dataset", "|U|", "|V|", "|E|", "butterflies", "θ_U^max", "θ_V^max", "θ_E^max"
    );
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    for p in presets {
        let g = p.build();
        let total = pbng::count::total_butterflies(&g, threads);
        let tu = tip_pbng(&g, Side::U, EngineConfig { threads, ..EngineConfig::tip() });
        let tv = tip_pbng(&g, Side::V, EngineConfig { threads, ..EngineConfig::tip() });
        let w = wing_pbng(&g, EngineConfig { threads, ..Default::default() });
        println!(
            "{:<12} {:>8} {:>8} {:>9} {:>12} {:>10} {:>10} {:>9}",
            p.name(),
            g.nu(),
            g.nv(),
            g.m(),
            human(total),
            tu.theta.iter().max().copied().unwrap_or(0),
            tv.theta.iter().max().copied().unwrap_or(0),
            w.theta.iter().max().copied().unwrap_or(0),
        );
    }
}
