//! Fig. 8 — strong scaling of PBNG wing decomposition vs thread count.
//!
//! NOTE (DESIGN.md §Substitutions): this container exposes a single CPU
//! core, so wall-clock speedup is not observable — threads beyond 1 are
//! oversubscribed. We report wall time (expect ≈flat), plus the
//! machine-independent witnesses of parallel structure: ρ (constant in T)
//! and output equality across T. On a real multicore this harness
//! reproduces the paper's speedup curve directly.

use pbng::graph::gen;
use pbng::wing::{wing_pbng, PbngConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let presets: &[gen::Preset] = if full {
        &[gen::Preset::TrS, gen::Preset::OrS, gen::Preset::TrM]
    } else {
        &[gen::Preset::TrS, gen::Preset::OrS]
    };
    println!("Fig. 8 — wing strong scaling (1-core container: see note in source)");
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>8} {:>10}",
        "dataset", "threads", "time(s)", "speedup", "ρ", "updates"
    );
    for p in presets {
        let g = p.build();
        let mut t1 = None;
        let mut base_theta = None;
        for threads in [1usize, 2, 4, 8] {
            let d = wing_pbng(&g, PbngConfig { p: 64, threads, ..Default::default() });
            let t = d.stats.total.as_secs_f64();
            let t1v = *t1.get_or_insert(t);
            if let Some(bt) = &base_theta {
                assert_eq!(&d.theta, bt, "outputs must not depend on T");
            } else {
                base_theta = Some(d.theta.clone());
            }
            println!(
                "{:<10} {:>8} {:>10.3} {:>10.2} {:>8} {:>10}",
                p.name(),
                threads,
                t,
                t1v / t,
                d.stats.rho,
                pbng::metrics::human(d.stats.updates)
            );
        }
    }
}
