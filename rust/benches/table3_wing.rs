//! Table 3 — wing decomposition comparison: execution time, support
//! updates, and synchronization rounds ρ for BUP / ParB / BE_Batch /
//! BE_PC / PBNG on every dataset.
//!
//! Shape to reproduce from the paper: PBNG lowest time; PBNG ρ orders of
//! magnitude below ParB/BE_Batch; PBNG updates at par with BE_PC and far
//! below BUP/ParB. Index-free baselines (BUP/ParB) are skipped above an
//! edge budget — the paper's own Table 3 has the same "-" entries where
//! baselines did not finish in 2 days. `--full` adds the medium tier and
//! lifts the budget.

use pbng::graph::gen;
use pbng::metrics::human;
use pbng::peel::Decomposition;
use pbng::wing::{wing_be_batch, wing_be_pc, wing_pbng, PbngConfig};

fn cell(d: &Decomposition, rho: bool) -> String {
    if rho {
        if d.stats.rho > 0 { d.stats.rho.to_string() } else { "-".into() }
    } else {
        format!("{:.2}s/{}", d.stats.total.as_secs_f64(), human(d.stats.updates))
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let budget = if full { usize::MAX } else { 40_000 };
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Table 3 — wing decomposition: time / updates (t/upd) and ρ");
    println!(
        "{:<12} {:>18} {:>18} {:>18} {:>18} {:>18} | {:>9} {:>9}",
        "dataset", "BUP", "ParB", "BE_Batch", "BE_PC", "PBNG", "ρ ParB", "ρ PBNG"
    );
    for p in presets {
        let g = p.build();
        let skip_slow = g.m() > budget;
        let bup = (!skip_slow).then(|| pbng::peel::bup::wing_bup(&g));
        let parb = (!skip_slow).then(|| pbng::peel::parb::wing_parb(&g, threads));
        let beb = wing_be_batch(&g, threads);
        let pc = wing_be_pc(&g, 0.02);
        let pbng_d = wing_pbng(&g, PbngConfig { p: 64, threads, ..Default::default() });
        // cross-check outputs
        assert_eq!(pbng_d.theta, beb.theta, "{}: PBNG != BE_Batch", p.name());
        assert_eq!(pbng_d.theta, pc.theta, "{}: PBNG != BE_PC", p.name());
        if let Some(b) = &bup {
            assert_eq!(pbng_d.theta, b.theta, "{}: PBNG != BUP", p.name());
        }
        println!(
            "{:<12} {:>18} {:>18} {:>18} {:>18} {:>18} | {:>9} {:>9}",
            p.name(),
            bup.as_ref().map(|d| cell(d, false)).unwrap_or_else(|| "-".into()),
            parb.as_ref().map(|d| cell(d, false)).unwrap_or_else(|| "-".into()),
            cell(&beb, false),
            cell(&pc, false),
            cell(&pbng_d, false),
            parb.as_ref().map(|d| cell(d, true)).unwrap_or_else(|| "-".into()),
            cell(&pbng_d, true),
        );
    }
}
