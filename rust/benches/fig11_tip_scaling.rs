//! Fig. 11 — strong scaling of PBNG tip decomposition vs thread count.
//!
//! Same single-core caveat as Fig. 8 (see DESIGN.md §Substitutions): we
//! report wall time (≈flat when oversubscribed), ρ (constant in T), and
//! assert output equality across T. On real multicore hardware this
//! harness reproduces the paper's speedup curve.

use pbng::graph::{gen, Side};
use pbng::tip::{tip_pbng, TipConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let presets: &[gen::Preset] = if full {
        &[gen::Preset::TrS, gen::Preset::OrS, gen::Preset::TrM, gen::Preset::OrM]
    } else {
        &[gen::Preset::TrS, gen::Preset::OrS]
    };
    println!("Fig. 11 — tip strong scaling (1-core container: see note in source)");
    println!(
        "{:<12} {:>8} {:>10} {:>10} {:>8} {:>10}",
        "dataset", "threads", "time(s)", "speedup", "ρ", "wedges"
    );
    for p in presets {
        let g = p.build();
        let mut t1 = None;
        let mut base_theta: Option<Vec<u64>> = None;
        for threads in [1usize, 2, 4, 8] {
            let d = tip_pbng(&g, Side::U, TipConfig { p: 32, threads, ..Default::default() });
            let t = d.stats.total.as_secs_f64();
            let t1v = *t1.get_or_insert(t);
            if let Some(bt) = &base_theta {
                assert_eq!(&d.theta, bt, "outputs must not depend on T");
            } else {
                base_theta = Some(d.theta.clone());
            }
            println!(
                "{:<12} {:>8} {:>10.3} {:>10.2} {:>8} {:>10}",
                p.name(),
                threads,
                t,
                t1v / t,
                d.stats.rho,
                pbng::metrics::human(d.stats.wedges)
            );
        }
    }
}
