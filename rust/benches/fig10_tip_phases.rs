//! Fig. 10 — contribution of each step to tip decomposition: initial
//! counting, PBNG CD peeling, PBNG FD peeling — as % of wedge traversal
//! and of execution time.
//!
//! Shape to reproduce: FD contributes <15% of wedge traversal (it runs
//! on induced subgraphs that preserve few wedges); when peeling the
//! heavy side, CD holds >70–80% of both wedges and time.

use pbng::graph::{gen, Side};
use pbng::metrics::Phase;
use pbng::tip::{tip_pbng, TipConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Fig. 10 — phase breakdown of PBNG tip decomposition (% of total)");
    println!(
        "{:<14} | {:>8} {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}",
        "dataset", "t:count", "t:CD", "t:part", "t:FD", "w:count", "w:CD", "w:FD"
    );
    for p in presets {
        let g = p.build();
        for side in [Side::U, Side::V] {
            let name = format!("{}{}", p.name(), if side == Side::U { "U" } else { "V" });
            let d = tip_pbng(&g, side, TipConfig { p: 32, threads, ..Default::default() });
            let tt = d.stats.total.as_secs_f64().max(1e-12);
            let tw = (d.stats.wedges as f64).max(1.0);
            let tp = |ph: Phase| 100.0 * d.stats.phase_time(ph).as_secs_f64() / tt;
            let wp = |ph: Phase| 100.0 * d.stats.phase_wedges(ph) as f64 / tw;
            // the generic engine records induced-subgraph construction
            // as its own Partition phase for tip too
            println!(
                "{:<14} | {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% | {:>7.1}% {:>7.1}% {:>7.1}%",
                name,
                tp(Phase::Count),
                tp(Phase::Coarse),
                tp(Phase::Partition),
                tp(Phase::Fine),
                wp(Phase::Count),
                wp(Phase::Coarse),
                wp(Phase::Fine),
            );
        }
    }
}
