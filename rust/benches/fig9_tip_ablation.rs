//! Fig. 9 — effect of the §5 optimizations on tip decomposition:
//! PBNG, PBNG− (no dynamic adjacency deletes), PBNG−− (additionally no
//! re-counting batch optimization). Reports time and wedges traversed,
//! normalized to full PBNG.
//!
//! Shape to reproduce: dynamic deletes cut wedge traversal up to ~1.4×;
//! re-counting dominates on wedge-heavy sides (paper: up to 68.8× on
//! TrU); sides whose Λ(activeSet) never exceeds Λ_cnt show PBNG− ≈ PBNG−−.

use pbng::graph::{gen, Side};
use pbng::metrics::human;
use pbng::tip::{tip_pbng, TipConfig};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let threads = pbng::par::default_threads();
    let mut presets: Vec<gen::Preset> = gen::Preset::all_small().to_vec();
    if full {
        presets.extend(gen::Preset::all_medium());
    }
    println!("Fig. 9 — tip optimization ablation (normalized to PBNG = 1.0)");
    println!(
        "{:<14} {:>20} {:>20}",
        "dataset", "time (−/−−)", "wedges (−/−−)"
    );
    for p in presets {
        let g = p.build();
        for side in [Side::U, Side::V] {
            let name = format!("{}{}", p.name(), if side == Side::U { "U" } else { "V" });
            let base = tip_pbng(&g, side, TipConfig { p: 32, threads, ..Default::default() });
            let minus = tip_pbng(
                &g,
                side,
                TipConfig { p: 32, threads, dynamic_deletes: false, ..Default::default() },
            );
            let minus2 = tip_pbng(
                &g,
                side,
                TipConfig { p: 32, threads, batch: false, dynamic_deletes: false, ..Default::default() },
            );
            assert_eq!(base.theta, minus.theta);
            assert_eq!(base.theta, minus2.theta);
            let r = |a: f64, b: f64| if b > 0.0 { a / b } else { f64::NAN };
            println!(
                "{:<14} {:>9.2}/{:<9.2} {:>9.2}/{:<9.2}  [PBNG: {:.3}s {}]",
                name,
                r(minus.stats.total.as_secs_f64(), base.stats.total.as_secs_f64()),
                r(minus2.stats.total.as_secs_f64(), base.stats.total.as_secs_f64()),
                r(minus.stats.wedges as f64, base.stats.wedges as f64),
                r(minus2.stats.wedges as f64, base.stats.wedges as f64),
                base.stats.total.as_secs_f64(),
                human(base.stats.wedges),
            );
        }
    }
}
