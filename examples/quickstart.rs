//! Quickstart: decompose the paper's Fig. 1 running example.
//!
//! Builds the small 1-wing graph, runs PBNG wing and tip decomposition,
//! and prints the dense-subgraph hierarchy (Fig. 1b: wing numbers 1–4).
//!
//! Run: `cargo run --release --example quickstart`

use pbng::beindex::BeIndex;
use pbng::engine::EngineConfig;
use pbng::graph::{gen, Side};
use pbng::hierarchy;
use pbng::tip::tip_pbng;
use pbng::wing::wing_pbng;

fn main() {
    let g = gen::paper_fig1();
    println!(
        "graph (paper Fig. 1 analog): |U|={} |V|={} |E|={}",
        g.nu(),
        g.nv(),
        g.m()
    );

    // --- wing decomposition (one EngineConfig drives both pipelines) ---
    let cfg = EngineConfig {
        p: 4,
        threads: 2,
        ..Default::default()
    };
    let wing = wing_pbng(&g, cfg);
    println!("\nwing numbers (θ_e):");
    for e in 0..g.m() as u32 {
        let (u, v) = g.edge(e);
        println!("  (u{u:<2} v{v:<2}) θ = {}", wing.theta[e as usize]);
    }

    // --- the hierarchy (Fig. 1b) ---------------------------------------
    let (idx, _) = BeIndex::build(&g, 1);
    println!("\nk-wing hierarchy:");
    println!("{:>4} {:>7} {:>12} {:>9}", "k", "edges", "components", "largest");
    for l in hierarchy::wing_hierarchy_summary(&g, &idx, &wing.theta) {
        println!(
            "{:>4} {:>7} {:>12} {:>9}",
            l.k, l.entities, l.components, l.largest
        );
    }

    // --- tip decomposition ----------------------------------------------
    let tip = tip_pbng(&g, Side::U, EngineConfig { p: 3, ..cfg });
    println!("\ntip numbers (θ_u, peeling U):");
    for u in 0..g.nu() {
        println!("  u{u:<2} θ = {}", tip.theta[u]);
    }

    println!(
        "\nmetrics: wing updates={} rho={} | tip wedges={} rho={}",
        wing.stats.updates, wing.stats.rho, tip.stats.wedges, tip.stats.rho
    );
}
