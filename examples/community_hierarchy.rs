//! Nested-community mining (paper §1 application: "mining nested
//! communities in social networks, where users affiliate with broad
//! groups and more specific sub-groups").
//!
//! We build a user × group membership graph with nested communities —
//! everyone in a broad community, a denser sub-community inside it, and a
//! core clique inside that — and show that wing decomposition recovers
//! the nesting as hierarchy levels (k-wings), exactly the structure of
//! the paper's Fig. 1b.
//!
//! Run: `cargo run --release --example community_hierarchy`

use pbng::beindex::BeIndex;
use pbng::graph::gen;
use pbng::hierarchy;
use pbng::wing::{wing_pbng, PbngConfig};

fn main() {
    // 4 nesting levels, innermost 6×6, outermost 48×48
    let g = gen::nested_blocks(4, 6, 2026);
    println!(
        "membership network: {} users × {} groups, {} memberships",
        g.nu(),
        g.nv(),
        g.m()
    );

    let d = wing_pbng(&g, PbngConfig { p: 16, threads: 2, ..Default::default() });
    let (idx, _) = BeIndex::build(&g, 1);
    hierarchy::check_wing_nesting(&g, &idx, &d.theta).expect("hierarchy must nest");

    let summary = hierarchy::wing_hierarchy_summary(&g, &idx, &d.theta);
    println!("\nfull k-wing hierarchy has {} levels; selected levels:", summary.len());
    println!("{:>8} {:>8} {:>12} {:>9}", "k", "edges", "components", "largest");
    // print ~10 evenly spaced levels
    let step = (summary.len() / 10).max(1);
    for l in summary.iter().step_by(step) {
        println!(
            "{:>8} {:>8} {:>12} {:>9}",
            l.k, l.entities, l.components, l.largest
        );
    }
    let top = summary.last().unwrap();
    println!(
        "\ndensest community: k = {} with {} edges (the innermost planted core)",
        top.k, top.entities
    );

    // the deepest level must concentrate in the planted inner blocks
    let core_edges = hierarchy::kwing_edges(&d.theta, top.k);
    let span = core_edges
        .iter()
        .map(|&e| {
            let (u, v) = g.edge(e);
            u.max(v)
        })
        .max()
        .unwrap_or(0);
    println!(
        "deepest level spans users/groups 0..{} (planted cores: 6, 12, 24, 48)",
        span + 1
    );
    assert!(
        span <= 24,
        "densest community should concentrate in the innermost planted blocks"
    );
    println!(
        "\nmetrics: updates={} rho={} time={:?}",
        pbng::metrics::human(d.stats.updates),
        d.stats.rho,
        d.stats.total
    );
}
