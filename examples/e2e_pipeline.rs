//! End-to-end driver: the full three-layer system on a realistic small
//! workload (EXPERIMENTS.md §E2E records a run of this binary).
//!
//! 1. Generate the trackers-like heavy-tail workload (tr-m preset,
//!    ~200k edges) — the scaled analog of the paper's headline dataset.
//! 2. Cross-validate the AOT path: the PJRT-executed XLA artifact
//!    (jax→Pallas→HLO text→rust) against sparse counting on a dense
//!    region of the graph.
//! 3. Run the full algorithm matrix on the small tier (tr-s): BUP, ParB,
//!    BE_Batch, BE_PC, PBNG — asserting identical outputs and printing a
//!    Table-3-shaped comparison (time / updates / ρ).
//! 4. Run PBNG vs the strongest baseline (BE_Batch) on the medium tier,
//!    plus tip decomposition of both sides, and extract the hierarchy.
//!
//! Run: `cargo run --release --example e2e_pipeline`

use pbng::count::dense::DenseCounter;
use pbng::graph::{gen, Side};
use pbng::metrics::human;
use pbng::peel::Decomposition;
use pbng::tip::{tip_pbng, TipConfig};
use pbng::wing::{wing_be_batch, wing_be_pc, wing_pbng, PbngConfig};

fn row(name: &str, d: &Decomposition) {
    println!(
        "  {:<10} {:>10.3}s {:>12} {:>12} {:>8}",
        name,
        d.stats.total.as_secs_f64(),
        human(d.stats.updates),
        human(d.stats.wedges),
        if d.stats.rho > 0 { d.stats.rho.to_string() } else { "-".into() }
    );
}

fn main() {
    let threads = pbng::par::default_threads().max(2);
    println!("=== PBNG end-to-end driver (threads = {threads}) ===\n");

    // ---- 1. workloads ---------------------------------------------------
    let small = gen::Preset::TrS.build();
    let medium = gen::Preset::TrM.build();
    let total_small = pbng::count::total_butterflies(&small, threads);
    let total_medium = pbng::count::total_butterflies(&medium, threads);
    println!("workload small  (tr-s): |U|={} |V|={} |E|={} butterflies={}",
        small.nu(), small.nv(), small.m(), human(total_small));
    println!("workload medium (tr-m): |U|={} |V|={} |E|={} butterflies={}",
        medium.nu(), medium.nv(), medium.m(), human(total_medium));

    // ---- 2. AOT artifact cross-check ------------------------------------
    println!("\n--- layer check: PJRT artifact vs sparse counting ---");
    let dc = DenseCounter::new();
    if dc.has_accelerator() {
        // densest region: top-degree vertices of the medium graph
        let mut us: Vec<u32> = (0..medium.nu() as u32).collect();
        us.sort_by_key(|&u| std::cmp::Reverse(medium.deg_u(u)));
        us.truncate(48);
        let mut vs: Vec<u32> = (0..medium.nv() as u32).collect();
        vs.sort_by_key(|&v| std::cmp::Reverse(medium.deg_v(v)));
        vs.truncate(48);
        let t0 = std::time::Instant::now();
        let accel = dc.count_block(&medium, &us, &vs);
        let t_accel = t0.elapsed();
        let t1 = std::time::Instant::now();
        let cpu = DenseCounter::cpu_only().count_block(&medium, &us, &vs);
        let t_cpu = t1.elapsed();
        assert_eq!(accel, cpu, "XLA artifact must match the rust mirror");
        println!(
            "  hot 48×48 block: {} butterflies — XLA(PJRT) {:?} vs rust {:?}  [outputs identical]",
            human(accel.total),
            t_accel,
            t_cpu
        );
    } else {
        println!("  (artifacts missing — run `make artifacts`; skipping accel check)");
    }

    // ---- 3. full algorithm matrix, small tier ----------------------------
    println!("\n--- wing decomposition, small tier (all algorithms) ---");
    println!(
        "  {:<10} {:>11} {:>12} {:>12} {:>8}",
        "algo", "time", "updates", "wedges/links", "rho"
    );
    let bup = pbng::peel::bup::wing_bup(&small);
    row("BUP", &bup);
    let parb = pbng::peel::parb::wing_parb(&small, threads);
    row("ParB", &parb);
    let beb = wing_be_batch(&small, threads);
    row("BE_Batch", &beb);
    let pc = wing_be_pc(&small, 0.02);
    row("BE_PC", &pc);
    let pb = wing_pbng(&small, PbngConfig { p: 32, threads, ..Default::default() });
    row("PBNG", &pb);
    assert_eq!(pb.theta, bup.theta, "PBNG must equal BUP");
    assert_eq!(parb.theta, bup.theta);
    assert_eq!(beb.theta, bup.theta);
    assert_eq!(pc.theta, bup.theta);
    println!(
        "  => outputs identical; PBNG rho reduction vs ParB: {:.0}×",
        parb.stats.rho as f64 / pb.stats.rho.max(1) as f64
    );

    // ---- 4. medium tier: PBNG vs strongest baseline + tip + hierarchy ----
    println!("\n--- wing decomposition, medium tier (PBNG vs BE_Batch) ---");
    println!(
        "  {:<10} {:>11} {:>12} {:>12} {:>8}",
        "algo", "time", "updates", "wedges/links", "rho"
    );
    let beb_m = wing_be_batch(&medium, threads);
    row("BE_Batch", &beb_m);
    let pb_m = wing_pbng(&medium, PbngConfig { p: 64, threads, ..Default::default() });
    row("PBNG", &pb_m);
    assert_eq!(pb_m.theta, beb_m.theta);
    println!(
        "  => identical outputs; rho {}× lower, updates {:.2}× lower",
        beb_m.stats.rho / pb_m.stats.rho.max(1),
        beb_m.stats.updates as f64 / pb_m.stats.updates.max(1) as f64
    );

    println!("\n--- tip decomposition, medium tier (both sides) ---");
    for side in [Side::U, Side::V] {
        let t = tip_pbng(&medium, side, TipConfig { p: 32, threads, ..Default::default() });
        println!(
            "  side {:?}: time={:?} wedges={} rho={} θ_max={}",
            side,
            t.stats.total,
            human(t.stats.wedges),
            t.stats.rho,
            t.theta.iter().max().unwrap()
        );
    }

    println!("\n--- hierarchy (medium tier) ---");
    let (idx, _) = pbng::beindex::BeIndex::build(&medium, threads);
    let summary = pbng::hierarchy::wing_hierarchy_summary(&medium, &idx, &pb_m.theta);
    println!(
        "  {} non-trivial k-wing levels; θ_E^max = {}; densest level: {} edges",
        summary.len(),
        summary.last().map(|l| l.k).unwrap_or(0),
        summary.last().map(|l| l.entities).unwrap_or(0)
    );
    println!("\n=== e2e pipeline complete — all cross-checks passed ===");
}
