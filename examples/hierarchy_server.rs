//! Hierarchy serving end to end: decompose once, persist the
//! nested-component forest, reload it, and answer queries — first through
//! the in-process engine, then over a real TCP session against the
//! poll-based reactor (`pbng::serve`, protocol v2), including a live
//! snapshot hot-swap mid-session.
//!
//! This is the ROADMAP "serve hierarchy queries, don't recompute them"
//! workload: the decomposition runs once at build time; every query after
//! that is a forest cut or a path walk over flat arrays.
//!
//! Run: `cargo run --release --example hierarchy_server`

use pbng::beindex::BeIndex;
use pbng::graph::gen;
use pbng::index::{build_wing_forest, codec, query::QueryEngine, server};
use pbng::serve::{Server, ServerConfig, SnapshotStore};
use pbng::wing::{wing_pbng, PbngConfig};
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::Ordering;

fn main() {
    // --- build: decompose + forest ------------------------------------
    let g = gen::Preset::PlantedS.build();
    println!(
        "graph: |U|={} |V|={} |E|={} (planted dense blocks preset)",
        g.nu(),
        g.nv(),
        g.m()
    );
    let t0 = std::time::Instant::now();
    let d = wing_pbng(&g, PbngConfig { p: 16, threads: 2, ..Default::default() });
    let (idx, _) = BeIndex::build(&g, 2);
    let forest = build_wing_forest(&g, &idx, &d.theta, 2);
    println!(
        "forest built in {:?}: {} nodes over {} levels, {} member edges",
        t0.elapsed(),
        forest.n_nodes(),
        forest.levels.len(),
        forest.n_members()
    );

    // --- persist + reload ----------------------------------------------
    let dir = std::env::temp_dir().join("pbng_hierarchy_server_example");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("planted.idx");
    let bytes = codec::save(&forest, &path).unwrap();
    let reloaded = codec::load(&path).unwrap();
    assert_eq!(forest, reloaded, "save/load must be lossless");
    println!("persisted to {} ({} bytes), reloaded identically", path.display(), bytes);

    // --- in-process queries --------------------------------------------
    let engine = QueryEngine::new(reloaded);
    let deepest = *engine.forest().levels.last().unwrap();
    println!("\nin-process session:");
    for cmd in [
        "stats".to_string(),
        "summary".to_string(),
        format!("kwing {deepest}"),
        format!("kwing {deepest}"), // repeat: served from the LRU cache
        "top 3".to_string(),
        "densest 0".to_string(),
    ] {
        match server::handle_command(&engine, &cmd) {
            server::Reply::Body(b) => {
                let first = b.lines().next().unwrap_or("");
                println!("  > {cmd}\n    {first}{}", if b.lines().count() > 1 { " …" } else { "" });
            }
            server::Reply::Quit => unreachable!(),
        }
    }
    println!(
        "cache: {} hits / {} misses over {} queries",
        engine.meters.cache_hits.get(),
        engine.meters.cache_misses.get(),
        engine.meters.queries.get()
    );

    // --- the same over TCP, through the reactor ------------------------
    // One thread serves every connection; sessions pin the snapshot that
    // was current when they connected (MVCC), so a publish mid-session
    // never disturbs them.
    let store = SnapshotStore::new(engine);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = Server::new(ServerConfig::new().max_conns(64).per_ip(16), store.clone());
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.run_on(listener).unwrap());

    println!("\nTCP session against {addr} (protocol v2):");
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, "membership 0\nkwing {deepest}\nstats\nquit").unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    for line in reader.lines() {
        let line = line.unwrap();
        if line.starts_with("OK ")
            || line.starts_with("ERR ")
            || line.starts_with("proto ")
            || line.starts_with("epoch ")
            || line.starts_with("components")
        {
            println!("  < {line}");
        }
    }

    // --- hot swap: publish a new epoch while the server runs -----------
    let engine2 = QueryEngine::new(codec::load(&path).unwrap());
    let epoch = store.publish(engine2);
    let mut s2 = std::net::TcpStream::connect(addr).unwrap();
    let mut greeting = String::new();
    let mut r2 = BufReader::new(s2.try_clone().unwrap());
    r2.read_line(&mut greeting).unwrap(); // OK hello
    greeting.clear();
    r2.read_line(&mut greeting).unwrap(); // proto 2 … epoch N
    println!("\nafter publish (epoch {epoch}), a new session greets with:");
    println!("  < {}", greeting.trim_end());
    assert!(greeting.contains(&format!("epoch {epoch}")));
    writeln!(s2, "quit").unwrap();

    stop.store(true, Ordering::Release);
    srv.join().unwrap();
    println!("\ndone: one decomposition, arbitrarily many queries.");
}
