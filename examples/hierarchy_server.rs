//! Hierarchy serving end to end: decompose once, persist the
//! nested-component forest, reload it, and answer queries — first through
//! the in-process engine, then over a real TCP session speaking the
//! `pbng serve` line protocol.
//!
//! This is the ROADMAP "serve hierarchy queries, don't recompute them"
//! workload: the decomposition runs once at build time; every query after
//! that is a forest cut or a path walk over flat arrays.
//!
//! Run: `cargo run --release --example hierarchy_server`

use pbng::beindex::BeIndex;
use pbng::graph::gen;
use pbng::index::{build_wing_forest, codec, query::QueryEngine, server};
use pbng::wing::{wing_pbng, PbngConfig};
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn main() {
    // --- build: decompose + forest ------------------------------------
    let g = gen::Preset::PlantedS.build();
    println!(
        "graph: |U|={} |V|={} |E|={} (planted dense blocks preset)",
        g.nu(),
        g.nv(),
        g.m()
    );
    let t0 = std::time::Instant::now();
    let d = wing_pbng(&g, PbngConfig { p: 16, threads: 2, ..Default::default() });
    let (idx, _) = BeIndex::build(&g, 2);
    let forest = build_wing_forest(&g, &idx, &d.theta, 2);
    println!(
        "forest built in {:?}: {} nodes over {} levels, {} member edges",
        t0.elapsed(),
        forest.n_nodes(),
        forest.levels.len(),
        forest.n_members()
    );

    // --- persist + reload ----------------------------------------------
    let dir = std::env::temp_dir().join("pbng_hierarchy_server_example");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("planted.idx");
    let bytes = codec::save(&forest, &path).unwrap();
    let reloaded = codec::load(&path).unwrap();
    assert_eq!(forest, reloaded, "save/load must be lossless");
    println!("persisted to {} ({} bytes), reloaded identically", path.display(), bytes);

    // --- in-process queries --------------------------------------------
    let engine = Arc::new(QueryEngine::new(reloaded));
    let deepest = *engine.forest().levels.last().unwrap();
    println!("\nin-process session:");
    for cmd in [
        "stats".to_string(),
        "summary".to_string(),
        format!("kwing {deepest}"),
        format!("kwing {deepest}"), // repeat: served from the LRU cache
        "top 3".to_string(),
        "densest 0".to_string(),
    ] {
        match server::handle_command(&engine, &cmd) {
            server::Reply::Body(b) => {
                let first = b.lines().next().unwrap_or("");
                println!("  > {cmd}\n    {first}{}", if b.lines().count() > 1 { " …" } else { "" });
            }
            server::Reply::Quit => unreachable!(),
        }
    }
    println!(
        "cache: {} hits / {} misses over {} queries",
        engine.meters.cache_hits.get(),
        engine.meters.cache_misses.get(),
        engine.meters.queries.get()
    );

    // --- the same over TCP ---------------------------------------------
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let srv = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            server::handle_connection(&engine, stream).unwrap();
        })
    };
    println!("\nTCP session against {addr}:");
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, "membership 0\nkwing {deepest}\nquit").unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    for line in reader.lines() {
        let line = line.unwrap();
        if line.starts_with("READY") || line == "END" || line == "BYE" || line.starts_with("components")
        {
            println!("  < {line}");
        }
    }
    srv.join().unwrap();
    println!("\ndone: one decomposition, arbitrarily many queries.");
}
