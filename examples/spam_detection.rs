//! Spam-reviewer detection in a rating network (paper §1 application:
//! "detecting spam reviewers that collectively rate selected items").
//!
//! We synthesize a user × product rating graph with organic heavy-tailed
//! behaviour, then inject a spam farm: a small set of accounts that
//! collectively rate the same sponsored items. Collective rating =
//! massive butterfly density among the spam accounts, so tip
//! decomposition surfaces them at the top of the hierarchy.
//!
//! Run: `cargo run --release --example spam_detection`

use pbng::graph::{gen, GraphBuilder, Side};
use pbng::testkit::Rng;
use pbng::tip::{tip_pbng, TipConfig};

const N_USERS: usize = 3_000;
const N_ITEMS: usize = 1_200;
const ORGANIC_EDGES: usize = 15_000;
const SPAMMERS: usize = 25;
const SPAM_ITEMS: usize = 20;

fn main() {
    // organic ratings: zipf-distributed users and items
    let organic = gen::zipf(N_USERS - SPAMMERS, N_ITEMS - SPAM_ITEMS, ORGANIC_EDGES, 0.65, 0.7, 99);
    let mut edges: Vec<(u32, u32)> = organic.edges().to_vec();
    // spam farm: the last SPAMMERS users all rate the last SPAM_ITEMS
    // items (with slight dropout), plus a little camouflage
    let mut rng = Rng::new(7);
    for s in 0..SPAMMERS {
        let u = (N_USERS - SPAMMERS + s) as u32;
        for t in 0..SPAM_ITEMS {
            if rng.chance(0.95) {
                edges.push((u, (N_ITEMS - SPAM_ITEMS + t) as u32));
            }
        }
        // camouflage: a few organic-looking ratings
        for _ in 0..3 {
            edges.push((u, rng.usize_below(N_ITEMS - SPAM_ITEMS) as u32));
        }
    }
    let g = GraphBuilder::new()
        .nu(N_USERS)
        .nv(N_ITEMS)
        .edges(&edges)
        .build();
    println!(
        "rating network: {} users × {} items, {} ratings ({} spam accounts hidden)",
        g.nu(),
        g.nv(),
        g.m(),
        SPAMMERS
    );

    let d = tip_pbng(&g, Side::U, TipConfig { p: 16, threads: 2, ..Default::default() });
    println!(
        "tip decomposition: {:?}, {} wedges traversed, rho = {}",
        d.stats.total,
        pbng::metrics::human(d.stats.wedges),
        d.stats.rho
    );

    // rank users by tip number
    let mut ranked: Vec<(usize, u64)> = d.theta.iter().copied().enumerate().collect();
    ranked.sort_by(|a, b| b.1.cmp(&a.1));
    println!("\ntop-{} users by tip number:", SPAMMERS + 4);
    let mut hits = 0;
    for (rank, (u, theta)) in ranked.iter().take(SPAMMERS + 4).enumerate() {
        let is_spam = *u >= N_USERS - SPAMMERS;
        if is_spam {
            hits += 1;
        }
        println!(
            "  #{:<3} user {:<5} θ = {:<8} {}",
            rank + 1,
            u,
            theta,
            if is_spam { "← planted spammer" } else { "" }
        );
    }
    let precision = hits as f64 / SPAMMERS as f64;
    println!(
        "\nrecovered {hits}/{SPAMMERS} planted spammers in the top-{} ({}% recall)",
        SPAMMERS + 4,
        (precision * 100.0) as u32
    );
    assert!(
        hits >= SPAMMERS * 3 / 4,
        "tip decomposition should surface the spam farm"
    );
}
